"""Multicore clip-dataset builder (the training half of the multicore
subsystem).

PR 4 made multicore *inference* real; this module makes the predictor
*train* on the contention it is asked to price.  Per mt.* benchmark and
checkpoint:

  1. ``multicore.run_multicore`` traces the interval — N per-core
     columnar traces over one shared memory, plus the deterministic
     commit interleave,
  2. ``timing.simulate_multicore`` assigns per-core commit cycles under
     the shared LLC / bus — so a clip's ground-truth runtime *includes*
     the stalls other cores inflicted on it,
  3. ``slicer.slice_multicore_columnar`` runs Algorithm 1 independently
     over each core's commit column (training-side commit-boundary
     slicing; inference keeps ``fixed_bounds``),
  4. the occurrence sampler thins each (benchmark, core) clip set on the
     same standardized-token content keys as the single-core build,
  5. a deterministic replay (``run_multicore`` with per-core
     ``snapshot_at``) snapshots each core's architectural state before
     every surviving clip — and, with ``peer_channels``, the *other*
     cores' states at the enclosing quantum start,
  6. the shared tokenize/pack pipeline (``standardize`` /
     ``dataset.pack_interval_clips``) emits the fixed-shape tensors.

Context layouts (widths all derive from ``context.context_len``):

  n_cores == 1            CONTEXT_LEN — the build degenerates to the
                          single-core pipeline bit for bit (the N=1
                          anchor: identical to ``build_dataset`` over
                          ``multicore.single_core_benchmark``),
  peer_channels == False  MULTICORE_CONTEXT_LEN — PR 4's core-tagged
                          inference layout,
  peer_channels == True   n_cores * MULTICORE_CONTEXT_LEN — one
                          ``<CORE>``-tagged register block per core,
                          self first, so the block encoder can attend
                          across cores and learn interference.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import context as ctx_mod
from repro.core import slicer as slicer_mod
from repro.core import standardize as std_mod
from repro.data.dataset import (BuildConfig, BuildStats, ClipDataset,
                                empty_dataset, pack_interval_clips,
                                sample_interval_clips)
from repro.isa import multicore, timing


@dataclasses.dataclass(frozen=True)
class MulticoreBuildConfig(BuildConfig):
    n_cores: int = 2
    quantum: int = multicore.DEFAULT_QUANTUM
    peer_channels: bool = False
    # close the sub-l_min residue after each core's final Algorithm-1
    # boundary as one extra clip (clip times then sum to the oracle's
    # per-core totals); off by default to stay bitwise with the
    # single-core slicer at N=1
    include_tail: bool = False

    @property
    def context_len(self) -> int:
        return ctx_mod.context_len(self.n_cores, self.peer_channels)


def _interval_core_context(mtrace: multicore.MulticoreTrace, core: int,
                           bcfg: MulticoreBuildConfig,
                           vocab: std_mod.Vocab) -> np.ndarray:
    """Step-5 context for one (checkpoint, core) replay: plain
    single-core rows at N=1, core-tagged rows otherwise, peer blocks
    appended when mixing is on."""
    snaps = mtrace.cores[core].snapshots
    if bcfg.n_cores == 1:
        return ctx_mod.context_tokens_from_matrix(snaps, vocab)
    if not bcfg.peer_channels:
        return ctx_mod.context_tokens_from_matrix(snaps, vocab,
                                                  core_id=core)
    return ctx_mod.peer_context_tokens(
        snaps, mtrace.peer_snapshots[core], core, vocab)


def build_multicore_bench_clips(mb: multicore.MulticoreBenchmark,
                                bcfg: MulticoreBuildConfig,
                                vocab: std_mod.Vocab,
                                stats: Optional[BuildStats] = None
                                ) -> ClipDataset:
    """Steps 1-6 for one multicore benchmark: (benchmark, core) clip
    shards whose ground-truth times are per-core commit-cycle deltas
    from the shared-resource oracle."""
    stats = stats if stats is not None else BuildStats()
    assert mb.n_cores == bcfg.n_cores, (mb.n_cores, bcfg.n_cores)
    cprogs = mb.compiled()
    tables = [cp.token_table(vocab, bcfg.l_token) for cp in cprogs]
    states = mb.fresh_states()
    t0 = time.time()
    multicore.run_multicore(cprogs, bcfg.warmup, states,
                            quantum=bcfg.quantum)
    stats.interpret_seconds += time.time() - t0

    parts: List[Tuple[np.ndarray, ...]] = []
    names: List[str] = []
    n_ckp = min(mb.ckp_num, bcfg.max_checkpoints)
    for _ in range(n_ckp):
        st_ckp = multicore.clone_states(states)         # replay anchor
        t0 = time.time()
        mtrace = multicore.run_multicore(cprogs, bcfg.interval_size,
                                         states, quantum=bcfg.quantum)
        stats.interpret_seconds += time.time() - t0
        if len(mtrace) == 0:
            break
        stats.n_instructions += len(mtrace)
        t0 = time.time()
        commits = timing.simulate_multicore(mtrace.cores, mtrace.schedule,
                                            bcfg.timing_params)
        stats.oracle_seconds += time.time() - t0
        t0 = time.time()
        sliced = slicer_mod.slice_multicore_columnar(
            commits, bcfg.l_min, include_tail=bcfg.include_tail)
        stats.slice_seconds += time.time() - t0

        rows_pc: List[Optional[np.ndarray]] = [None] * mb.n_cores
        keeps: List[List[int]] = [[] for _ in range(mb.n_cores)]
        starts: List[List[int]] = [[] for _ in range(mb.n_cores)]
        for c, (bounds, _) in enumerate(sliced):
            if not len(bounds):
                continue
            stats.n_sliced += len(bounds)
            rows_pc[c] = tables[c][mtrace.cores[c].pc]
            keeps[c] = sample_interval_clips(rows_pc[c], bounds, bcfg,
                                             stats)
            starts[c] = bounds[keeps[c], 0].tolist() if keeps[c] else []
        if not any(keeps):
            continue
        t0 = time.time()
        replay = multicore.run_multicore(
            cprogs, bcfg.interval_size, st_ckp, quantum=bcfg.quantum,
            snapshot_at=starts,
            peer_snapshots=bcfg.peer_channels and mb.n_cores > 1)
        stats.replay_seconds += time.time() - t0
        for c in range(mb.n_cores):
            if not keeps[c]:
                continue
            bounds, times = sliced[c]
            snaps = replay.cores[c].snapshots
            assert snaps.shape[0] == len(keeps[c]), \
                (c, snaps.shape, len(keeps[c]))
            t0 = time.time()
            ctx = _interval_core_context(replay, c, bcfg, vocab)
            stats.context_seconds += time.time() - t0
            parts.append(pack_interval_clips(rows_pc[c], bounds, times,
                                             keeps[c], ctx, bcfg, stats))
            names.extend([_shard_name(mb, c)] * len(keeps[c]))

    if not parts:
        return empty_dataset(bcfg, bcfg.context_len)
    return ClipDataset(np.concatenate([p[0] for p in parts]),
                       np.concatenate([p[1] for p in parts]),
                       np.concatenate([p[2] for p in parts]),
                       np.concatenate([p[3] for p in parts]), names)


def _shard_name(mb: multicore.MulticoreBenchmark, core: int) -> str:
    """(benchmark, core) provenance; at N=1 the bare benchmark name, so
    the N=1 build is identical to ``build_dataset`` in names too."""
    return mb.name if mb.n_cores == 1 else f"{mb.name}#c{core}"


def build_multicore_dataset(bench_names: Sequence[str],
                            bcfg: MulticoreBuildConfig,
                            vocab: Optional[std_mod.Vocab] = None,
                            verbose: bool = False,
                            stats: Optional[BuildStats] = None
                            ) -> ClipDataset:
    """The multicore mirror of ``build_dataset``: one ``ClipDataset`` of
    (benchmark, core) shards over the mt.* suite."""
    vocab = vocab or std_mod.build_vocab()
    parts = []
    for name in bench_names:
        t0 = time.time()
        mb = multicore.build_multicore_benchmark(name, bcfg.n_cores)
        part = build_multicore_bench_clips(mb, bcfg, vocab, stats=stats)
        parts.append(part)
        if verbose:
            print(f"  {name} x{bcfg.n_cores}: {len(part)} clips "
                  f"({time.time()-t0:.1f}s)")
    ds = ClipDataset.concat(parts)
    assert ds.context_len == bcfg.context_len or len(ds) == 0, \
        (ds.context_len, bcfg.context_len)
    return ds.validate()
