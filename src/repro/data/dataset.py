"""Clip dataset pipeline (paper Fig 2): benchmarks -> intervals -> timed
traces -> sliced clips -> sampled + tokenized tensors.

Per benchmark checkpoint (interval):
  1. functional warm-up, then trace the interval (columnar funcsim over
     the benchmark's ``CompiledProgram``),
  2. O3 oracle assigns commit cycles (columnar ``isa/timing``) — the
     golden runtimes,
  3. Algorithm 1 slices the trace into (start, end) clip bounds
     (``slicer.slice_trace_columnar``: one np.diff + a greedy pass),
  4. the occurrence sampler thins the clip set (core/sampler) — clip
     content keys are the bytes of gathered standardized-token rows,
  5. a replay pass snapshots the architectural context at each surviving
     clip's start (the CPU state *before* the clip, §V-B) into a uint64
     snapshot matrix,
  6. a token-table gather + vectorized byte decomposition produce the
     fixed-shape int32 tensors — no per-instruction Python.

The arrays are plain numpy: each data-parallel host builds/loads its own
shard (clips are i.i.d., so sharding is a pure range split — see
``shard_range``), and ``batches`` yields ready-to-jit dict batches.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import context as ctx_mod
from repro.core import sampler as sampler_mod
from repro.core import slicer as slicer_mod
from repro.core import standardize as std_mod
from repro.isa import funcsim, progen, timing


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    interval_size: int = 20_000       # paper: 5M; scaled for offline CPU
    warmup: int = 2_000               # paper: 1M
    max_checkpoints: int = 4          # cap Table II counts for wall time
    l_min: int = 100                  # paper §IV-B
    l_clip: int = 128                 # pad target (l_min..~l_min+width)
    l_token: int = 16
    threshold: int = 200              # sampler occurrence threshold
    coef: float = 0.02                # sampler coefficient
    sample: bool = True
    timing_params: timing.TimingParams = timing.TimingParams()


@dataclasses.dataclass
class ClipDataset:
    clip_tokens: np.ndarray           # (N, l_clip, l_token) int32
    context_tokens: np.ndarray        # (N, 360) int32
    clip_mask: np.ndarray             # (N, l_clip) float32
    time: np.ndarray                  # (N,) float32
    bench_names: List[str]            # provenance per clip

    def __len__(self) -> int:
        return self.clip_tokens.shape[0]

    def select(self, idx: np.ndarray) -> "ClipDataset":
        return ClipDataset(self.clip_tokens[idx], self.context_tokens[idx],
                           self.clip_mask[idx], self.time[idx],
                           [self.bench_names[i] for i in idx])

    @staticmethod
    def concat(parts: Sequence["ClipDataset"]) -> "ClipDataset":
        return ClipDataset(
            np.concatenate([p.clip_tokens for p in parts]),
            np.concatenate([p.context_tokens for p in parts]),
            np.concatenate([p.clip_mask for p in parts]),
            np.concatenate([p.time for p in parts]),
            sum((p.bench_names for p in parts), []))

    def save(self, path) -> None:
        np.savez_compressed(
            path, clip_tokens=self.clip_tokens,
            context_tokens=self.context_tokens, clip_mask=self.clip_mask,
            time=self.time, bench_names=np.array(self.bench_names))

    @staticmethod
    def load(path) -> "ClipDataset":
        z = np.load(path, allow_pickle=False)
        return ClipDataset(z["clip_tokens"], z["context_tokens"],
                           z["clip_mask"], z["time"],
                           [str(s) for s in z["bench_names"]])


def _gather_clip(rows: np.ndarray, start: int, end: int, lead_dup: bool,
                 l_clip: int) -> Tuple[np.ndarray, int]:
    """Token rows for one columnar clip (clip 0 carries Algorithm 1's
    duplicated leading instruction), truncated to ``l_clip``."""
    body = rows[start:end]
    if lead_dup:
        body = np.concatenate([rows[:1], body])
    k = min(body.shape[0], l_clip)
    return body[:k], k


def build_bench_clips(bench: progen.Benchmark, bcfg: BuildConfig,
                      vocab: std_mod.Vocab) -> ClipDataset:
    """Steps 1-6 for one benchmark, entirely on the columnar IR."""
    cprog = bench.compiled()
    token_table = cprog.token_table(vocab, bcfg.l_token)
    st = progen.fresh_compiled_state(bench)
    _, st = funcsim.run_compiled(cprog, bcfg.warmup, st)

    tok_list, ctx_list, mask_list, time_list = [], [], [], []
    n_ckp = min(bench.ckp_num, bcfg.max_checkpoints)
    for _ in range(n_ckp):
        st_ckp = st.clone()                             # replay anchor
        trace, st = funcsim.run_compiled(cprog, bcfg.interval_size, st)
        if not len(trace):
            break
        commits = timing.simulate_columnar(trace, bcfg.timing_params)
        bounds, times = slicer_mod.slice_trace_columnar(commits, bcfg.l_min)
        if not len(bounds):
            continue
        rows = token_table[trace.pc]
        if bcfg.sample:
            # content key = the clip's standardized-token bytes: exactly
            # what Fig-5 standardization preserves of the instructions
            keys = [_gather_clip(rows, int(s), int(e), j == 0,
                                 10 ** 9)[0].tobytes()
                    for j, (s, e) in enumerate(bounds)]
            keep, _ = sampler_mod.sample_indices(keys, bcfg.threshold,
                                                 bcfg.coef)
        else:
            keep = list(range(len(bounds)))
        if not keep:
            continue
        starts = bounds[keep, 0].tolist()
        replay, _ = funcsim.run_compiled(cprog, bcfg.interval_size, st_ckp,
                                         snapshot_at=starts)
        snaps = replay.snapshots
        assert snaps.shape[0] == len(keep), (snaps.shape, len(keep))
        ctx_list.append(ctx_mod.context_tokens_from_matrix(snaps, vocab))
        for row_i, j in enumerate(keep):
            body, k = _gather_clip(rows, int(bounds[j, 0]),
                                   int(bounds[j, 1]), j == 0, bcfg.l_clip)
            toks = np.zeros((bcfg.l_clip, bcfg.l_token), np.int32)
            toks[:k] = body
            mask = np.zeros(bcfg.l_clip, np.float32)
            mask[:k] = 1.0
            tok_list.append(toks)
            mask_list.append(mask)
            time_list.append(float(times[j]))

    n = len(tok_list)
    if n == 0:
        return ClipDataset(
            np.zeros((0, bcfg.l_clip, bcfg.l_token), np.int32),
            np.zeros((0, ctx_mod.CONTEXT_LEN), np.int32),
            np.zeros((0, bcfg.l_clip), np.float32),
            np.zeros((0,), np.float32), [])
    return ClipDataset(np.stack(tok_list), np.concatenate(ctx_list),
                       np.stack(mask_list),
                       np.asarray(time_list, np.float32),
                       [bench.name] * n)


def build_dataset(bench_names: Sequence[str], bcfg: BuildConfig,
                  vocab: Optional[std_mod.Vocab] = None,
                  verbose: bool = False) -> ClipDataset:
    vocab = vocab or std_mod.build_vocab()
    parts = []
    for name in bench_names:
        t0 = time.time()
        part = build_bench_clips(progen.build_benchmark(name), bcfg, vocab)
        parts.append(part)
        if verbose:
            print(f"  {name}: {len(part)} clips ({time.time()-t0:.1f}s)")
    return ClipDataset.concat(parts)


def build_set_datasets(bcfg: BuildConfig,
                       vocab: Optional[std_mod.Vocab] = None,
                       verbose: bool = False) -> Dict[int, ClipDataset]:
    """The six Table-II benchmark sets (Fig 11 train/test protocol)."""
    vocab = vocab or std_mod.build_vocab()
    out = {}
    for s in progen.SET_NUMBERS:
        names = [b.name for b in progen.benchmarks_in_set(s)]
        out[s] = build_dataset(names, bcfg, vocab, verbose=verbose)
    return out


def split_dataset(ds: ClipDataset, fractions=(0.8, 0.1, 0.1),
                  seed: int = 0) -> Tuple[ClipDataset, ...]:
    """Random 80/10/10 split (paper §VI-B method 1)."""
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds))
    out = []
    lo = 0
    for i, f in enumerate(fractions):
        hi = len(ds) if i == len(fractions) - 1 else lo + int(f * len(ds))
        out.append(ds.select(idx[lo:hi]))
        lo = hi
    return tuple(out)


def indexed_clips(ds: ClipDataset) -> Tuple[np.ndarray, np.ndarray]:
    """Dedupe a dataset's instruction rows for RT-cache-style serving:
    returns ``(row_table (n_unique, l_token) int32, rt_idx (N, l_clip)
    int32)`` with ``row_table[rt_idx]`` bitwise equal to
    ``ds.clip_tokens``.

    Traces are loopy, so n_unique is orders of magnitude below N x l_clip
    — this is both a storage compression and the bridge to cache-aware
    evaluation: ``RTCache.ensure_rows(row_table)`` maps local row ids to
    global ones, after which every eval batch is an ``rt_idx`` gather
    through ``predictor.forward_cached``.  When the dataset has any
    masked (all-<PAD>) slot the all-zero row occupies local row 0
    (``dedupe_token_rows``), matching the cache's pad slot.
    """
    n, l_clip, l_token = ds.clip_tokens.shape
    uniq, inv = std_mod.dedupe_token_rows(
        ds.clip_tokens.reshape(n * l_clip, l_token))
    return uniq, inv.reshape(n, l_clip)


def shard_range(n: int, host: int, n_hosts: int) -> Tuple[int, int]:
    """Contiguous per-host shard bounds (clips are i.i.d.)."""
    per = n // n_hosts
    lo = host * per
    hi = n if host == n_hosts - 1 else lo + per
    return lo, hi


def batches(ds: ClipDataset, batch_size: int, seed: int = 0,
            shuffle: bool = True, epochs: int = 1,
            include_time: bool = True) -> Iterator[Dict[str, np.ndarray]]:
    """Yields dict batches; short final batches are dropped (fixed shapes
    keep XLA from recompiling)."""
    n = len(ds)
    rng = np.random.RandomState(seed)
    for _ in range(epochs):
        order = rng.permutation(n) if shuffle else np.arange(n)
        for lo in range(0, n - batch_size + 1, batch_size):
            idx = order[lo: lo + batch_size]
            b = {"clip_tokens": ds.clip_tokens[idx],
                 "context_tokens": ds.context_tokens[idx],
                 "clip_mask": ds.clip_mask[idx]}
            if include_time:
                b["time"] = ds.time[idx]
            yield b
