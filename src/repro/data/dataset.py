"""Clip dataset pipeline (paper Fig 2): benchmarks -> intervals -> timed
traces -> sliced clips -> sampled + tokenized tensors.

Per benchmark checkpoint (interval):
  1. functional warm-up, then trace the interval (columnar funcsim over
     the benchmark's ``CompiledProgram``),
  2. O3 oracle assigns commit cycles (columnar ``isa/timing``) — the
     golden runtimes,
  3. Algorithm 1 slices the trace into (start, end) clip bounds
     (``slicer.slice_trace_columnar``: one np.diff + a greedy pass),
  4. the occurrence sampler thins the clip set (core/sampler) — clip
     content keys are the bytes of gathered standardized-token rows,
  5. a replay pass snapshots the architectural context at each surviving
     clip's start (the CPU state *before* the clip, §V-B) into a uint64
     snapshot matrix,
  6. a token-table gather + vectorized byte decomposition produce the
     fixed-shape int32 tensors — no per-instruction Python.

The arrays are plain numpy: each data-parallel host builds/loads its own
shard (clips are i.i.d., so sharding is a pure range split — see
``shard_range``), and ``batches`` yields ready-to-jit dict batches.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import context as ctx_mod
from repro.core import sampler as sampler_mod
from repro.core import slicer as slicer_mod
from repro.core import standardize as std_mod
from repro.isa import funcsim, progen, timing


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    interval_size: int = 20_000       # paper: 5M; scaled for offline CPU
    warmup: int = 2_000               # paper: 1M
    max_checkpoints: int = 4          # cap Table II counts for wall time
    l_min: int = 100                  # paper §IV-B
    l_clip: int = 128                 # pad target (l_min..~l_min+width)
    l_token: int = 16
    threshold: int = 200              # sampler occurrence threshold
    coef: float = 0.02                # sampler coefficient
    sample: bool = True
    timing_params: timing.TimingParams = timing.TimingParams()


@dataclasses.dataclass
class BuildStats:
    """Per-stage wall-time breakdown across a dataset build — the
    dataset-build analogue of the engine's ``FrontendStats``, reported by
    ``bench_speed --dataset-build`` so build throughput joins the perf
    trajectory."""

    interpret_seconds: float = 0.0    # functional warmup + interval traces
    oracle_seconds: float = 0.0       # commit-cycle ground truth
    slice_seconds: float = 0.0        # Algorithm-1 bounds
    sample_seconds: float = 0.0       # content keys + occurrence sampler
    replay_seconds: float = 0.0       # snapshot replay pass
    tokenize_seconds: float = 0.0     # token-row gather + clip packing
    context_seconds: float = 0.0      # snapshot byte decomposition
    n_instructions: int = 0
    n_sliced: int = 0                 # clips before sampling
    n_clips: int = 0                  # clips kept in the dataset

    @property
    def build_seconds(self) -> float:
        return (self.interpret_seconds + self.oracle_seconds
                + self.slice_seconds + self.sample_seconds
                + self.replay_seconds + self.tokenize_seconds
                + self.context_seconds)

    def as_dict(self) -> Dict[str, float]:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)} | {
                    "build_seconds": self.build_seconds}


@dataclasses.dataclass
class ClipDataset:
    clip_tokens: np.ndarray           # (N, l_clip, l_token) int32
    # (N, M) int32 — M is ctx_mod.context_len(n_cores, peer_channels):
    # CONTEXT_LEN single-core, MULTICORE_CONTEXT_LEN core-tagged,
    # n_cores such blocks with peer channels mixed in
    context_tokens: np.ndarray
    clip_mask: np.ndarray             # (N, l_clip) float32
    time: np.ndarray                  # (N,) float32
    bench_names: List[str]            # provenance per clip

    def __len__(self) -> int:
        return self.clip_tokens.shape[0]

    @property
    def context_len(self) -> int:
        return self.context_tokens.shape[1]

    def validate(self) -> "ClipDataset":
        """Dataset-build boundary check: consistent clip counts and a
        recognized context layout (no stale hard-coded widths)."""
        n = len(self)
        assert self.context_tokens.shape[0] == n, self.context_tokens.shape
        assert self.clip_mask.shape[0] == n, self.clip_mask.shape
        assert self.time.shape[0] == n, self.time.shape
        assert len(self.bench_names) == n, (len(self.bench_names), n)
        ctx_mod.validate_context_width(self.context_len, "ClipDataset")
        return self

    def select(self, idx: np.ndarray) -> "ClipDataset":
        return ClipDataset(self.clip_tokens[idx], self.context_tokens[idx],
                           self.clip_mask[idx], self.time[idx],
                           [self.bench_names[i] for i in idx])

    @staticmethod
    def concat(parts: Sequence["ClipDataset"]) -> "ClipDataset":
        return ClipDataset(
            np.concatenate([p.clip_tokens for p in parts]),
            np.concatenate([p.context_tokens for p in parts]),
            np.concatenate([p.clip_mask for p in parts]),
            np.concatenate([p.time for p in parts]),
            sum((p.bench_names for p in parts), []))

    def save(self, path) -> None:
        np.savez_compressed(
            path, clip_tokens=self.clip_tokens,
            context_tokens=self.context_tokens, clip_mask=self.clip_mask,
            time=self.time, bench_names=np.array(self.bench_names))

    @staticmethod
    def load(path) -> "ClipDataset":
        z = np.load(path, allow_pickle=False)
        return ClipDataset(z["clip_tokens"], z["context_tokens"],
                           z["clip_mask"], z["time"],
                           [str(s) for s in z["bench_names"]])


def empty_dataset(bcfg: BuildConfig,
                  context_len: Optional[int] = None) -> ClipDataset:
    """Zero-clip dataset with the build's tensor shapes (the degenerate
    part both builders emit for a clip-less benchmark)."""
    m = ctx_mod.CONTEXT_LEN if context_len is None else context_len
    return ClipDataset(
        np.zeros((0, bcfg.l_clip, bcfg.l_token), np.int32),
        np.zeros((0, m), np.int32),
        np.zeros((0, bcfg.l_clip), np.float32),
        np.zeros((0,), np.float32), [])


def sample_interval_clips(rows: np.ndarray, bounds: np.ndarray,
                          bcfg: BuildConfig,
                          stats: BuildStats) -> List[int]:
    """Step 4 (shared by the single- and multicore builds): occurrence-
    sample one interval's Algorithm-1 clips on their standardized-token
    content keys; ``bcfg.sample=False`` keeps everything."""
    t0 = time.time()
    if bcfg.sample:
        # content key = the clip's standardized-token bytes: exactly
        # what Fig-5 standardization preserves of the instructions
        keys = std_mod.bounded_clip_keys(rows, bounds)
        keep, _ = sampler_mod.sample_indices(keys, bcfg.threshold,
                                             bcfg.coef)
    else:
        keep = list(range(len(bounds)))
    stats.sample_seconds += time.time() - t0
    return keep


def pack_interval_clips(rows: np.ndarray, bounds: np.ndarray,
                        times: np.ndarray, keep: Sequence[int],
                        ctx: np.ndarray, bcfg: BuildConfig,
                        stats: BuildStats
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
    """Step 6 (shared): tokenize the kept clips of one interval into the
    fixed-shape dataset tensors; ``ctx`` is the already-built context
    matrix for the same kept clips (step 5)."""
    assert ctx.shape[0] == len(keep), (ctx.shape, len(keep))
    t0 = time.time()
    toks, mask = std_mod.encode_bounded_clips(rows, bounds, keep,
                                              bcfg.l_clip)
    t = np.asarray([float(times[j]) for j in keep], np.float32)
    stats.tokenize_seconds += time.time() - t0
    stats.n_clips += len(keep)
    return toks, ctx, mask, t


def build_bench_clips(bench: progen.Benchmark, bcfg: BuildConfig,
                      vocab: std_mod.Vocab,
                      stats: Optional[BuildStats] = None) -> ClipDataset:
    """Steps 1-6 for one benchmark, entirely on the columnar IR."""
    stats = stats if stats is not None else BuildStats()
    cprog = bench.compiled()
    token_table = cprog.token_table(vocab, bcfg.l_token)
    st = progen.fresh_compiled_state(bench)
    t0 = time.time()
    _, st = funcsim.run_compiled(cprog, bcfg.warmup, st)
    stats.interpret_seconds += time.time() - t0

    parts: List[Tuple[np.ndarray, ...]] = []
    n_ckp = min(bench.ckp_num, bcfg.max_checkpoints)
    for _ in range(n_ckp):
        st_ckp = st.clone()                             # replay anchor
        t0 = time.time()
        trace, st = funcsim.run_compiled(cprog, bcfg.interval_size, st)
        stats.interpret_seconds += time.time() - t0
        if not len(trace):
            break
        stats.n_instructions += len(trace)
        t0 = time.time()
        commits = timing.simulate_columnar(trace, bcfg.timing_params)
        stats.oracle_seconds += time.time() - t0
        t0 = time.time()
        bounds, times = slicer_mod.slice_trace_columnar(commits, bcfg.l_min)
        stats.slice_seconds += time.time() - t0
        if not len(bounds):
            continue
        stats.n_sliced += len(bounds)
        rows = token_table[trace.pc]
        keep = sample_interval_clips(rows, bounds, bcfg, stats)
        if not keep:
            continue
        starts = bounds[keep, 0].tolist()
        t0 = time.time()
        replay, _ = funcsim.run_compiled(cprog, bcfg.interval_size, st_ckp,
                                         snapshot_at=starts)
        stats.replay_seconds += time.time() - t0
        snaps = replay.snapshots
        assert snaps.shape[0] == len(keep), (snaps.shape, len(keep))
        t0 = time.time()
        ctx = ctx_mod.context_tokens_from_matrix(snaps, vocab)
        stats.context_seconds += time.time() - t0
        parts.append(pack_interval_clips(rows, bounds, times, keep, ctx,
                                         bcfg, stats))

    if not parts:
        return empty_dataset(bcfg)
    n = sum(p[0].shape[0] for p in parts)
    return ClipDataset(np.concatenate([p[0] for p in parts]),
                       np.concatenate([p[1] for p in parts]),
                       np.concatenate([p[2] for p in parts]),
                       np.concatenate([p[3] for p in parts]),
                       [bench.name] * n)


def build_dataset(bench_names: Sequence[str], bcfg: BuildConfig,
                  vocab: Optional[std_mod.Vocab] = None,
                  verbose: bool = False,
                  stats: Optional[BuildStats] = None) -> ClipDataset:
    vocab = vocab or std_mod.build_vocab()
    parts = []
    for name in bench_names:
        t0 = time.time()
        part = build_bench_clips(progen.build_benchmark(name), bcfg, vocab,
                                 stats=stats)
        parts.append(part)
        if verbose:
            print(f"  {name}: {len(part)} clips ({time.time()-t0:.1f}s)")
    return ClipDataset.concat(parts).validate()


def build_set_datasets(bcfg: BuildConfig,
                       vocab: Optional[std_mod.Vocab] = None,
                       verbose: bool = False) -> Dict[int, ClipDataset]:
    """The six Table-II benchmark sets (Fig 11 train/test protocol)."""
    vocab = vocab or std_mod.build_vocab()
    out = {}
    for s in progen.SET_NUMBERS:
        names = [b.name for b in progen.benchmarks_in_set(s)]
        out[s] = build_dataset(names, bcfg, vocab, verbose=verbose)
    return out


def split_dataset(ds: ClipDataset, fractions=(0.8, 0.1, 0.1),
                  seed: int = 0) -> Tuple[ClipDataset, ...]:
    """Random 80/10/10 split (paper §VI-B method 1)."""
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds))
    out = []
    lo = 0
    for i, f in enumerate(fractions):
        hi = len(ds) if i == len(fractions) - 1 else lo + int(f * len(ds))
        out.append(ds.select(idx[lo:hi]))
        lo = hi
    return tuple(out)


def indexed_clips(ds: ClipDataset) -> Tuple[np.ndarray, np.ndarray]:
    """Dedupe a dataset's instruction rows for RT-cache-style serving:
    returns ``(row_table (n_unique, l_token) int32, rt_idx (N, l_clip)
    int32)`` with ``row_table[rt_idx]`` bitwise equal to
    ``ds.clip_tokens``.

    Traces are loopy, so n_unique is orders of magnitude below N x l_clip
    — this is both a storage compression and the bridge to cache-aware
    evaluation: ``RTCache.ensure_rows(row_table)`` maps local row ids to
    global ones, after which every eval batch is an ``rt_idx`` gather
    through ``predictor.forward_cached``.  When the dataset has any
    masked (all-<PAD>) slot the all-zero row occupies local row 0
    (``dedupe_token_rows``), matching the cache's pad slot.
    """
    n, l_clip, l_token = ds.clip_tokens.shape
    uniq, inv = std_mod.dedupe_token_rows(
        ds.clip_tokens.reshape(n * l_clip, l_token))
    return uniq, inv.reshape(n, l_clip)


def shard_range(n: int, host: int, n_hosts: int) -> Tuple[int, int]:
    """Contiguous per-host shard bounds (clips are i.i.d.)."""
    per = n // n_hosts
    lo = host * per
    hi = n if host == n_hosts - 1 else lo + per
    return lo, hi


def batches(ds: ClipDataset, batch_size: int, seed: int = 0,
            shuffle: bool = True, epochs: int = 1,
            include_time: bool = True) -> Iterator[Dict[str, np.ndarray]]:
    """Yields dict batches; short final batches are dropped (fixed shapes
    keep XLA from recompiling)."""
    n = len(ds)
    rng = np.random.RandomState(seed)
    for _ in range(epochs):
        order = rng.permutation(n) if shuffle else np.arange(n)
        for lo in range(0, n - batch_size + 1, batch_size):
            idx = order[lo: lo + batch_size]
            b = {"clip_tokens": ds.clip_tokens[idx],
                 "context_tokens": ds.context_tokens[idx],
                 "clip_mask": ds.clip_mask[idx]}
            if include_time:
                b["time"] = ds.time[idx]
            yield b
