from repro.data.dataset import (  # noqa: F401
    BuildConfig, BuildStats, ClipDataset, batches, build_dataset,
    build_set_datasets, split_dataset)
from repro.data.multicore_dataset import (  # noqa: F401
    MulticoreBuildConfig, build_multicore_dataset)
