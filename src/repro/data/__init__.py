from repro.data.dataset import (  # noqa: F401
    ClipDataset, BuildConfig, build_dataset, build_set_datasets, batches,
    split_dataset)
